"""Flat parameter-plane layer: FlatSpec round-trips, batched-kernel parity
vs the pure-jnp oracles, the weight-semantics contract, degenerate
mini-batch sampling, and tree-path vs plane-path engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cefl_paper import ClassifierConfig
from repro.core import aggregation, fedprox
from repro.core.round_step import CEFLHyper, build_cefl_round_step, \
    make_dpu_meta
from repro.kernels import ops, ref
from repro.kernels.fedprox_update import LANE, fedprox_accum_2d
from repro.kernels.plane import ParamPlane, as_tree, spec_of
from repro.models.classifier import classifier_loss, init_classifier_params

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------- FlatSpec round-trip -----

def _assert_tree_equal(a_tree, b_tree):
    for a, b in zip(jax.tree_util.tree_leaves(a_tree),
                    jax.tree_util.tree_leaves(b_tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.parametrize("tree", [
    # odd leaf shapes
    {"w": jax.random.normal(KEY, (37, 13)),
     "b": jax.random.normal(KEY, (7,)),
     "nested": {"u": jax.random.normal(KEY, (2, 3, 5))}},
    # scalar + empty leaves
    {"s": jnp.asarray(1.5), "e": jnp.zeros((0, 4)),
     "v": jnp.arange(11, dtype=jnp.float32)},
    # bf16 params (f32 plane holds bf16 exactly)
    {"w": jax.random.normal(KEY, (33, 9)).astype(jnp.bfloat16),
     "b": jax.random.normal(KEY, (129,)).astype(jnp.bfloat16)},
], ids=["odd-shapes", "empty-and-scalar", "bf16"])
def test_flatspec_roundtrip(tree):
    spec = spec_of(tree)
    assert spec.rows % 8 == 0
    _assert_tree_equal(spec.unflatten(spec.flatten(tree)), tree)
    # ParamPlane view round-trips too, batched included
    plane = ParamPlane.from_tree(tree)
    _assert_tree_equal(plane.to_tree(), tree)
    stacked = plane.broadcast(3)
    batched = stacked.to_tree()
    _assert_tree_equal(
        jax.tree_util.tree_map(lambda x: x[1], batched), tree)


def test_spec_is_cached_and_hashable():
    t1 = {"w": jnp.zeros((5, 5))}
    t2 = {"w": jnp.ones((5, 5))}
    assert spec_of(t1) is spec_of(t2)       # same structure, one spec
    assert hash(spec_of(t1)) == hash(spec_of(t2))
    assert spec_of(t1) != spec_of({"w": jnp.zeros((5, 6))})


# -------------------------------------------- batched kernel vs oracle -----

@pytest.mark.parametrize("anchor_kind", ["shared", "per_group"])
@pytest.mark.parametrize("G,R", [(1, 8), (3, 16), (5, 64)])
def test_fedprox_accum_kernel_vs_ref(anchor_kind, G, R):
    x = jax.random.normal(KEY, (G, R, LANE))
    g = jax.random.normal(jax.random.PRNGKey(1), (G, R, LANE))
    acc = jax.random.normal(jax.random.PRNGKey(2), (G, R, LANE))
    anc2 = jax.random.normal(jax.random.PRNGKey(3), (R, LANE))
    anc = anc2 if anchor_kind == "shared" else \
        jnp.broadcast_to(anc2[None], x.shape) * 1.1
    coef = jnp.linspace(1.0, 0.5, G)
    active = (jnp.arange(G) % 2).astype(jnp.float32)
    out = fedprox_accum_2d(x, g, anc, acc, coef, active, 0.1, 0.05,
                           interpret=True)
    exp = ref.fedprox_accum_ref(x, g, anc, acc, coef, active, 0.1, 0.05)
    np.testing.assert_allclose(out[0], exp[0], atol=1e-6)
    np.testing.assert_allclose(out[1], exp[1], atol=1e-6)


def test_nova_stacked_kernel_vs_ref():
    n, R = 4, 16
    x = jax.random.normal(KEY, (n, R, LANE))
    d = jax.random.normal(jax.random.PRNGKey(1), (n, R, LANE))
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    out = ops.nova_aggregate_plane(x, d, w, 0.07)
    exp = ref.nova_aggregate_ref(x, d, w, 0.07)
    np.testing.assert_allclose(out, exp, atol=1e-5)


# ------------------------------------------------ weight contract -----

def test_weight_contract_absolute_sizes_one_normalization():
    """All tree-level aggregation entry points take ABSOLUTE D_i and
    normalize once; scaling the weights must not change the result
    (regression for ops.nova_aggregate silently re-normalizing while
    round_step expected pre-normalized weights)."""
    params = {"w": jax.random.normal(KEY, (33, 9))}
    ds = [jax.tree_util.tree_map(lambda x: (i + 1) * 0.1 * x, params)
          for i in range(3)]
    for w_abs in ([100.0, 300.0, 100.0], [0.2, 0.6, 0.2]):
        out_ops = ops.nova_aggregate(params, ds, w_abs, 0.02)
        out_agg = aggregation.aggregate(params, ds, w_abs, theta=1.0,
                                        eta=0.02)
        np.testing.assert_allclose(out_ops["w"], out_agg["w"], atol=1e-5)
    # scaled vs normalized weights: identical everywhere
    a = aggregation.aggregate(params, ds, [1.0, 3.0, 1.0], theta=2.0,
                              eta=0.1)
    b = aggregation.aggregate(params, ds, [0.2, 0.6, 0.2], theta=2.0,
                              eta=0.1)
    np.testing.assert_allclose(a["w"], b["w"], atol=1e-6)


def test_round_step_accepts_absolute_weights():
    cfg = ClassifierConfig(input_shape=(6, 6, 1), hidden=(16,))
    p0 = init_classifier_params(KEY, cfg)
    n_dpu, mb = 2, 8
    x = jax.random.normal(KEY, (n_dpu, 1, mb, 6, 6, 1))
    y = jax.random.randint(KEY, (n_dpu, 1, mb), 0, 10)

    def loss_fn(p, micro, mask):
        return classifier_loss(p, {"x": micro["x"], "y": micro["y"]},
                               mask), {}

    step = jax.jit(build_cefl_round_step(
        loss_fn, CEFLHyper(eta=0.05, mu=0.01, gamma_max=2)))
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (n_dpu,) + l.shape), p0)
    meta_abs = make_dpu_meta(n_dpu, gammas=[2, 2], weights=[300.0, 100.0])
    meta_norm = make_dpu_meta(n_dpu, gammas=[2, 2], weights=[0.75, 0.25])
    out_abs, _ = step(stacked, {"x": x, "y": y}, meta_abs)
    out_norm, _ = step(stacked, {"x": x, "y": y}, meta_norm)
    for k in out_abs:
        np.testing.assert_allclose(out_abs[k], out_norm[k], atol=1e-7)


# ------------------------------------- degenerate mini-batch sampling -----

def test_sample_minibatch_clamps_and_handles_empty():
    idx = fedprox.sample_minibatch(KEY, 4, 1.0)
    assert len(idx) == 4 and len(set(np.asarray(idx).tolist())) == 4
    # m*D rounds above D -> clamped to D (used to fault in choice)
    idx = fedprox.sample_minibatch(KEY, 3, 1.2)
    assert len(idx) == 3
    # D == 0 (degenerate offloading split) -> empty, no fault
    idx = fedprox.sample_minibatch(KEY, 0, 0.5)
    assert idx.shape == (0,)
    # tiny m still yields one example
    assert len(fedprox.sample_minibatch(KEY, 50, 1e-6)) == 1


@pytest.mark.parametrize("backend", ["plane", "tree"])
def test_local_train_handles_empty_dataset(backend):
    """A D == 0 DPU (degenerate offloading split) trains nothing instead
    of faulting: params unchanged, d_i = 0, nan loss."""
    cfg = ClassifierConfig(input_shape=(6, 6, 1), hidden=(16,))
    p0 = init_classifier_params(KEY, cfg)
    empty = {"x": jnp.zeros((0, 6, 6, 1)), "y": jnp.zeros((0,), jnp.int32)}
    data = {"x": jax.random.normal(KEY, (8, 6, 6, 1)),
            "y": jax.random.randint(KEY, (8,), 0, 10)}
    r = fedprox.local_train(p0, classifier_loss, empty, gamma=2,
                            m_frac=0.5, eta=0.05, mu=0.01, key=KEY,
                            backend=backend)
    assert r.num_examples == 0 and np.isnan(r.loss)
    _assert_tree_equal(as_tree(r.params), p0)
    assert all(not np.any(np.asarray(x))
               for x in jax.tree_util.tree_leaves(as_tree(r.d_i)))
    # mixed batch: empty DPUs skipped, live ones match an all-live run
    keys = list(jax.random.split(KEY, 3))
    mixed = fedprox.local_train_batched(
        p0, classifier_loss, [data, empty, data], gamma=2, m_frac=1.0,
        eta=0.05, mu=0.01, keys=keys, backend=backend)
    assert mixed[1].num_examples == 0
    alive = fedprox.local_train_batched(
        p0, classifier_loss, [data, data], gamma=2, m_frac=1.0,
        eta=0.05, mu=0.01, keys=[keys[0], keys[2]], backend=backend)
    for a, b in zip(jax.tree_util.tree_leaves(as_tree(mixed[2].params)),
                    jax.tree_util.tree_leaves(as_tree(alive[1].params))):
        np.testing.assert_allclose(a, b, atol=1e-6)


# ----------------------------------------- engine plane/tree parity -----

def _mini_engine(executor):
    from repro.core import (Engine, EngineOptions, MLConstants)
    from repro.data import make_image_dataset, make_online_ues
    from repro.models.classifier import classifier_accuracy
    from repro.network import NetworkConfig, make_network
    from repro.solver import ObjectiveWeights
    net = make_network(NetworkConfig(num_ue=4, num_bs=2, num_dc=2))
    (trx, tr_y), (tex, te_y) = make_image_dataset(1200, (8, 8, 1))
    ccfg = ClassifierConfig(input_shape=(8, 8, 1), hidden=(16,))
    p0 = init_classifier_params(KEY, ccfg)
    consts = MLConstants(L=5.0, theta_i=np.ones(6) * 2,
                         sigma_i=np.ones(6) * 3, zeta1=2.0, zeta2=1.0)
    eng = Engine(net, "fixed:0", consts=consts, ow=ObjectiveWeights(),
                 opts=EngineOptions(rounds=3, eta=0.1, solver_outer=2),
                 executor=executor)
    ues = make_online_ues(trx, tr_y, num_ue=4, mean_arrivals=120,
                          std_arrivals=12, seed=0)

    def eval_fn(p):
        return classifier_accuracy(p, jnp.asarray(tex[:200]),
                                   jnp.asarray(te_y[:200]))

    return eng.run(ues, init_params=p0, loss_fn=classifier_loss,
                   eval_fn=eval_fn)


def test_engine_plane_path_matches_tree_path():
    """SimExecutor loss/params series on the plane path must match the
    pre-refactor tree path within float tolerance."""
    from repro.core import SimExecutor
    res_plane = _mini_engine(SimExecutor(use_plane=True))
    res_tree = _mini_engine(SimExecutor(use_plane=False))
    np.testing.assert_allclose(res_plane.series("loss"),
                               res_tree.series("loss"), atol=1e-4)
    np.testing.assert_allclose(res_plane.series("acc"),
                               res_tree.series("acc"), atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(res_plane.params),
                    jax.tree_util.tree_leaves(res_tree.params)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_engine_mesh_plane_path_matches_tree_path():
    from repro.core import MeshExecutor
    res_plane = _mini_engine(MeshExecutor(use_plane=True))
    res_tree = _mini_engine(MeshExecutor(use_plane=False))
    np.testing.assert_allclose(res_plane.series("loss"),
                               res_tree.series("loss"), atol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(res_plane.params),
                    jax.tree_util.tree_leaves(res_tree.params)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_local_train_plane_results_unflatten_at_boundary():
    """keep_planes=True returns ParamPlane-backed results; as_tree is the
    API-boundary conversion and matches the default tree output."""
    cfg = ClassifierConfig(input_shape=(6, 6, 1), hidden=(16,))
    p0 = init_classifier_params(KEY, cfg)
    data = {"x": jax.random.normal(KEY, (16, 6, 6, 1)),
            "y": jax.random.randint(KEY, (16,), 0, 10)}
    kw = dict(gamma=2, m_frac=1.0, eta=0.05, mu=0.01, key=KEY)
    r_plane = fedprox.local_train(p0, classifier_loss, data,
                                  keep_planes=True, **kw)
    r_tree = fedprox.local_train(p0, classifier_loss, data, **kw)
    assert isinstance(r_plane.params, ParamPlane)
    for a, b in zip(jax.tree_util.tree_leaves(as_tree(r_plane.params)),
                    jax.tree_util.tree_leaves(r_tree.params)):
        np.testing.assert_allclose(a, b, atol=1e-6)
