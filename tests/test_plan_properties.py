"""Property-based tests (hypothesis, or the deterministic fallback shim) for
the plan/data plumbing the solver feeds: ``RoundPlan.from_w``/``to_w``
round-trips and ``realize_offloading`` datapoint conservation under
arbitrary offload matrices.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.api import PLAN_KEYS, RoundPlan
from repro.core.engine import realize_offloading
from repro.network import NetworkConfig, make_network
from repro.solver.variables import init_w, project, round_indicators

_NETS = {}


def _net(n, b, s):
    key = (n, b, s)
    if key not in _NETS:
        _NETS[key] = make_network(NetworkConfig(num_ue=n, num_bs=b,
                                                num_dc=s, seed=n + b + s))
    return _NETS[key]


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 10_000))
def test_roundplan_w_roundtrip(n, b, s, seed):
    net = _net(n, b, s)
    rng = np.random.RandomState(seed)
    w = init_w(net, np.full(n, 500.0))
    w = {k: np.asarray(v) * (1.0 + 0.5 * rng.rand(*np.shape(v)))
         for k, v in w.items()}
    w = round_indicators(project(w, net))
    plan = RoundPlan.from_w(w)
    back = plan.to_w()
    assert set(back) == set(PLAN_KEYS)
    for k in PLAN_KEYS:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(w[k]),
                                      err_msg=k)
    # a second round-trip is the identity
    again = RoundPlan.from_w(back).to_w()
    for k in PLAN_KEYS:
        np.testing.assert_array_equal(np.asarray(again[k]),
                                      np.asarray(back[k]))


def test_roundplan_from_w_extra_and_missing_keys():
    net = _net(4, 2, 2)
    w = round_indicators(project(init_w(net, np.full(4, 100.0)), net))
    w_extra = dict(w, scratch=np.zeros(3))
    assert RoundPlan.from_w(w_extra).aggregator == \
        int(np.argmax(np.asarray(w["I_s"])))
    w_missing = {k: v for k, v in w.items() if k != "rho_bs"}
    with pytest.raises(KeyError, match="rho_bs"):
        RoundPlan.from_w(w_missing)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 10_000), st.floats(0.0, 1.5))
def test_realize_offloading_conserves_datapoints(n, b, s, seed, rho_scale):
    """Every input point lands at exactly one DPU for ARBITRARY nonnegative
    offload matrices — including rows summing past 1 (clawed back) and
    rho_bs rows that floor every share to zero."""
    net = _net(n, b, s)
    rng = np.random.RandomState(seed)
    w = {
        "rho_nb": rho_scale * rng.rand(n, b),
        "rho_bs": rng.rand(b, s) * rng.randint(0, 2, (b, s)),
    }
    sizes = rng.randint(0, 60, n)
    data = [{"x": rng.randn(d, 3).astype(np.float32),
             "y": rng.randint(0, 5, d)} for d in sizes]
    ue_data, dc_data = realize_offloading(
        np.random.RandomState(seed + 1), data, w, net)
    n_ue = sum(len(d["y"]) for d in ue_data)
    n_dc = sum(0 if d is None else len(d["y"]) for d in dc_data)
    assert n_ue + n_dc == int(sizes.sum())
    # every UE with data keeps at least one point (all-offload guard)
    for d_in, d_out in zip(sizes, ue_data):
        if d_in > 0:
            assert len(d_out["y"]) >= 1
    # label multiset is preserved end-to-end
    all_y = np.concatenate(
        [np.asarray(d["y"]) for d in ue_data if len(d["y"])] +
        [np.asarray(d["y"]) for d in dc_data if d is not None])
    in_y = np.concatenate([d["y"] for d in data if len(d["y"])]) \
        if sizes.sum() else np.array([])
    np.testing.assert_array_equal(np.sort(all_y), np.sort(in_y))
