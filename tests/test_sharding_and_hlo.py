"""Sharding spec rules + HLO cost walker + dry-run plumbing (small mesh)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import steps as ST
from repro.models import lm as L
from repro.sharding.specs import param_specs, sanitize_spec
from repro.utils.hlo import collective_bytes, shape_bytes
from repro.utils.hlo_walk import amplified_costs


def _mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_specs_cover_all_leaves():
    for arch in ["qwen3-32b", "jamba-v0.1-52b", "whisper-medium"]:
        cfg = get_config(arch)
        p = jax.eval_shape(lambda c=cfg: L.init_lm_params(
            jax.random.PRNGKey(0), c))
        specs = param_specs(cfg, p)
        leaves_p = jax.tree_util.tree_leaves(p)
        leaves_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 64), axis=st.sampled_from(["data", "model"]))
def test_sanitize_spec_divisibility(dim, axis):
    try:
        mesh = jax.sharding.AbstractMesh((2, 4), ("data", "model"))
    except TypeError:   # jax <= 0.4.x signature: tuple of (name, size)
        mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    spec = sanitize_spec(P(axis), (dim,), mesh)
    size = mesh.shape[axis]
    if dim % size == 0:
        assert spec == P(axis)
    else:
        assert spec == P(None)


def test_shape_bytes():
    assert shape_bytes("bf16[128,1024]") == 128 * 1024 * 2
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("pred[8]") == 8


def test_walker_amplifies_nested_scans():
    def f(a):
        def body(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ a), None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        c, _ = jax.lax.scan(body, jnp.eye(128), None, length=8)
        return c

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    res = amplified_costs(comp.as_text())
    expect = 32 * 2 * 128 ** 3
    assert abs(res["flops"] - expect) / expect < 0.05
    assert not res["unknown_trip_counts"]


def test_collective_parsers_on_hlo_text():
    # single-device compiles elide collectives, so test on crafted HLO.
    # hlo.collective_bytes reads inline operand shapes (quick diagnostic);
    # hlo_walk.amplified_costs resolves %name operands via symbol tables
    # (the authoritative path used by the roofline).
    hlo = """
ENTRY %main (p: f32[128,64]) -> f32[128,64] {
  %p = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p), replica_groups={}
  %ag = bf16[256,64]{1,0} all-gather(bf16[128,64]{1,0} %x), dimensions={0}
  ROOT %r = f32[128,64]{1,0} copy(%ar)
}
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 128 * 64 * 2     # inline shape counted
    amp = amplified_costs(hlo)
    assert amp["collectives"]["all-reduce"] == 128 * 64 * 4  # via table


def test_make_plan_rules():
    # whisper skips long_500k; dense gets a window variant; ssm native
    p = ST.make_plan("whisper-medium", "long_500k", multi_pod=False)
    assert p.skip
    p = ST.make_plan("qwen3-32b", "long_500k", multi_pod=False)
    assert p.cfg.sliding_window == ST.SW_LONG and not p.skip
    p = ST.make_plan("starcoder2-15b", "long_500k", multi_pod=False)
    assert p.cfg.sliding_window == 4096
    p = ST.make_plan("mamba2-130m", "long_500k", multi_pod=False)
    assert not p.seq_shard_decode and not p.skip
    p = ST.make_plan("jamba-v0.1-52b", "long_500k", multi_pod=False)
    assert p.wide_cache
    # train microbatching keeps per-microbatch examples = data axis
    p = ST.make_plan("llama3-405b", "train_4k", multi_pod=False)
    assert p.mb * p.n_micro * p.n_dpu == 256
    assert p.remat_chunk > 1


def test_input_specs_are_abstract():
    p = ST.make_plan("whisper-medium", "train_4k", multi_pod=True)
    spec = ST.input_specs(p)
    assert set(spec) == {"tokens", "labels", "enc_embed"}
    for leaf in jax.tree_util.tree_leaves(spec):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert spec["tokens"].shape[0] == 2      # 2 DPUs on the multi-pod mesh


@pytest.mark.slow
def test_dryrun_subprocess_one_combo(tmp_path):
    """Full dry-run path in its own process (512 host devices)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-130m", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert (tmp_path / "mamba2-130m_decode_32k_single.json").exists()
