"""Theorem 1 / Corollary 1 / Proposition 1 properties (hypothesis-based)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import estimation, fedprox
from repro.core.convergence import (MLConstants, a_norm_stats,
                                    corollary_bound, step_size_condition,
                                    theorem1_bound)


def _consts(n=5, theta=2.0, sigma=1.5, z2=1.0):
    return MLConstants(L=4.0, theta_i=np.full(n, theta),
                       sigma_i=np.full(n, sigma), zeta1=2.0, zeta2=z2,
                       F0_gap=2.3)


def _bound(m=0.5, gamma=2.0, drift=10.0, theta_i=2.0, n=5, D=2000.0):
    c = _consts(n, theta=theta_i)
    return theorem1_bound(
        consts=c, p_i=np.full(n, 1 / n), D_i=np.full(n, D),
        m_i=np.full(n, m), gamma_i=np.full(n, gamma),
        tau_sum_drift=drift, eta=1e-2, theta=1.0, T=50)["total"]


@settings(max_examples=30, deadline=None)
@given(m=st.floats(0.05, 1.0), gamma=st.floats(1.0, 10.0),
       drift=st.floats(0.0, 100.0))
def test_bound_positive(m, gamma, drift):
    assert _bound(m=m, gamma=gamma, drift=drift) > 0


@settings(max_examples=20, deadline=None)
@given(m=st.floats(0.05, 0.9))
def test_bound_decreases_with_minibatch_ratio(m):
    assert _bound(m=m + 0.05) <= _bound(m=m) + 1e-9


@settings(max_examples=20, deadline=None)
@given(th=st.floats(0.5, 5.0))
def test_bound_increases_with_variability(th):
    assert _bound(theta_i=th + 0.5) >= _bound(theta_i=th) - 1e-9


def test_bound_increases_with_drift():
    assert _bound(drift=50) > _bound(drift=5)


def test_heterogeneity_term_grows_with_gamma():
    c = _consts(z2=5.0)
    b1 = theorem1_bound(consts=c, p_i=np.full(5, .2), D_i=np.full(5, 2000.),
                        m_i=np.full(5, .5), gamma_i=np.full(5, 2.),
                        tau_sum_drift=0, eta=1e-2, theta=1., T=50)
    b2 = theorem1_bound(consts=c, p_i=np.full(5, .2), D_i=np.full(5, 2000.),
                        m_i=np.full(5, .5), gamma_i=np.full(5, 8.),
                        tau_sum_drift=0, eta=1e-2, theta=1., T=50)
    assert b2["heterogeneity"] > b1["heterogeneity"]


def test_corollary_rate_is_one_over_sqrt_T():
    # gamma_bar: per-round total local iterations (bounded in T; with the
    # literal cumulative reading the first term of eq. 33 would be O(1))
    c = _consts()
    vals = []
    for T in (100, 400):
        d, gbar = 5, 5 * 2.0
        vals.append(corollary_bound(consts=c, d=d, gamma_bar=gbar, T=T,
                                    theta=1.0, tau_tilde=1.0, m_min=0.5,
                                    gamma_max=2.0))
    # quadrupling T should roughly halve the bound (dominant 1/sqrt(T))
    assert vals[1] < vals[0] * 0.75


def test_a_norm_stats_match_explicit():
    a = fedprox.a_coefficients(5, 0.05, 0.2)
    a1, a2, alast = a_norm_stats(5, 0.05, 0.2)
    np.testing.assert_allclose(a1, float(jnp.sum(a)), rtol=1e-6)
    np.testing.assert_allclose(a2, float(jnp.sum(a * a)), rtol=1e-6)
    np.testing.assert_allclose(alast, float(a[-1]), rtol=1e-6)


def test_step_size_condition_monotone():
    assert step_size_condition([2.0], eta=1e-3, mu=0.01, L=1.0, zeta1=1.0)
    assert not step_size_condition([50.0], eta=1.0, mu=0.01, L=10.0,
                                   zeta1=5.0)


@settings(max_examples=15, deadline=None)
@given(m=st.floats(0.1, 1.0), D=st.integers(10, 500))
def test_prop1_variance_bound_holds_empirically(m, D):
    """Empirical SGD variance (without replacement) <= Prop. 1 bound for a
    linear model where Theta is exact."""
    rng = np.random.RandomState(0)
    xs = rng.randn(D, 4).astype(np.float32)
    # linear regression loss grad per example: (w.x - 0) x -> grad = x x^T w
    w = rng.randn(4).astype(np.float32)

    def grad_of(idx):
        X = xs[idx]
        return (X @ w)[:, None] * X   # per-example grads (n, 4)

    full = grad_of(np.arange(D)).mean(0)
    bsz = max(1, int(round(m * D)))
    trials = []
    for t in range(200):
        idx = rng.choice(D, bsz, replace=False)
        g = grad_of(idx).mean(0)
        trials.append(np.sum((g - full) ** 2))
    emp = np.mean(trials)
    # Theta: Lipschitz const of grad wrt example = max ||grad diff||/||x diff||
    G = grad_of(np.arange(D))
    num = np.linalg.norm(G[:, None] - G[None], axis=-1)
    den = np.linalg.norm(xs[:, None] - xs[None], axis=-1) + 1e-12
    theta = float((num / den).max())
    sigma2 = float(np.mean(np.sum((xs - xs.mean(0)) ** 2, axis=1)))
    bound = estimation.sgd_variance_bound(bsz / D, D, np.sqrt(sigma2), theta)
    assert emp <= bound * 1.05 + 1e-9, (emp, bound)
