"""Mamba-2 SSD: chunked dual form == recurrent scan; state chaining."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models.mamba import (init_mamba_params, init_mamba_state,
                                mamba_decode_step, ssd_forward)

S_CFG = SSMConfig(state_dim=8, head_dim=8, expand=2, chunk_size=4,
                  conv_width=4)


def _setup(B=2, S=16, d_model=16, seed=0):
    p = init_mamba_params(jax.random.PRNGKey(seed), d_model, S_CFG,
                          jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, d_model)) * .5
    return p, x


def test_ssd_equals_recurrence():
    p, x = _setup()
    y_chunk, st = ssd_forward(p, x, S_CFG, return_state=True)
    cur = init_mamba_state(2, 16, S_CFG, jnp.float32)
    ys = []
    for t in range(x.shape[1]):
        y, cur = mamba_decode_step(p, x[:, t], cur, S_CFG)
        ys.append(y)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_rec, atol=1e-5)
    np.testing.assert_allclose(st["h"], cur["h"], atol=1e-6)
    np.testing.assert_allclose(st["conv"], cur["conv"], atol=1e-6)


def test_ssd_state_chaining():
    p, x = _setup(S=16)
    y_full = ssd_forward(p, x, S_CFG)
    y1, st = ssd_forward(p, x[:, :8], S_CFG, return_state=True)
    y2 = ssd_forward(p, x[:, 8:], S_CFG, init_state=st)
    np.testing.assert_allclose(y_full, jnp.concatenate([y1, y2], 1),
                               atol=1e-5)


@pytest.mark.parametrize("chunk", [2, 8, 16])
def test_ssd_chunk_invariance(chunk):
    import dataclasses
    p, x = _setup(S=16)
    cfg2 = dataclasses.replace(S_CFG, chunk_size=chunk)
    y1 = ssd_forward(p, x, S_CFG)
    y2 = ssd_forward(p, x, cfg2)
    np.testing.assert_allclose(y1, y2, atol=1e-5)


def test_ssd_grads_finite():
    p, x = _setup()
    g = jax.grad(lambda pp: jnp.sum(ssd_forward(pp, x, S_CFG) ** 2))(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert jnp.all(jnp.isfinite(leaf))
