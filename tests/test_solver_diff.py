"""Differential harness: the jitted batched solver backend vs the numpy
Python-loop oracle (``solver/ref.py``) across a seeded grid of random
``NetworkConfig``s, including degenerate topologies (single BS, disconnected
server mesh, zero-data UE).

Parity contract (ISSUE 3): objective within 1e-4 relative, identical
rounded plans, and matching feasibility residuals on every grid point.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.convergence import MLConstants
from repro.network import NetworkConfig, make_network
from repro.solver import ObjectiveWeights, PDHyper, constraint_vector, sca
from repro.solver.variables import NetView, WSpec, init_w, project

OW = ObjectiveWeights()
PD = PDHyper(max_iters=3, consensus_rounds=15)


def _consts(net):
    nd = net.cfg.num_ue + net.cfg.num_dc
    rng = np.random.RandomState(net.cfg.seed + 7)
    return MLConstants(L=4.0, theta_i=rng.uniform(1.0, 3.0, nd),
                       sigma_i=rng.uniform(0.5, 1.5, nd),
                       zeta1=2.0, zeta2=1.0)


def _d_bar(net, zero_ue=False):
    rng = np.random.RandomState(net.cfg.seed + 13)
    D = rng.normal(1000.0, 100.0, net.cfg.num_ue).clip(100)
    if zero_ue:
        D[0] = 0.0
    return D


def _cut_server_mesh(net):
    """Disconnect the DC-DC part of the consensus graph (degenerate mesh)."""
    N, B, S = net.dims
    A = np.array(net.adjacency)
    A[N + B:, N + B:] = 0
    return dataclasses.replace(net, adjacency=A)


GRID = [
    # (cfg, degenerate transform, zero-data UE)
    (NetworkConfig(num_ue=6, num_bs=3, num_dc=2, seed=0), None, False),
    (NetworkConfig(num_ue=5, num_bs=1, num_dc=2, seed=1), None, False),
    (NetworkConfig(num_ue=8, num_bs=4, num_dc=3, seed=2), None, True),
    (NetworkConfig(num_ue=6, num_bs=3, num_dc=3, seed=3),
     _cut_server_mesh, False),
]


def _solve_both(net, D_bar, distributed):
    consts = _consts(net)
    kw = dict(distributed=distributed, max_outer=2, pd=PD)
    return (sca.solve(net, D_bar, consts, OW, backend="ref", **kw),
            sca.solve(net, D_bar, consts, OW, backend="jit", **kw))


def _assert_parity(net, D_bar, res_ref, res_jit):
    # objective trajectory: 1e-4 relative agreement at every outer iterate
    ref_h = np.asarray(res_ref.objective_history)
    jit_h = np.asarray(res_jit.objective_history)
    assert ref_h.shape == jit_h.shape
    np.testing.assert_allclose(jit_h, ref_h, rtol=1e-4)
    # identical rounded plans (the executable decision)
    for k in ("I_s", "I_nb", "I_bn"):
        np.testing.assert_array_equal(
            np.asarray(res_ref.w_rounded[k]), np.asarray(res_jit.w_rounded[k]),
            err_msg=f"rounded {k} differs")
    # continuous decisions agree tightly in physical units
    for k in ("rho_nb", "rho_bs", "f_n", "z_s", "gamma", "m", "R_bs"):
        a, b = np.asarray(res_ref.w[k]), np.asarray(res_jit.w[k])
        scale = max(1.0, float(np.abs(a).max()))
        np.testing.assert_allclose(b, a, atol=5e-3 * scale,
                                   err_msg=f"relaxed {k} differs")
    # feasibility residuals of the rounded plan match
    v_ref = np.asarray(constraint_vector(res_ref.w_rounded, net, D_bar))
    v_jit = np.asarray(constraint_vector(res_jit.w_rounded, net, D_bar))
    scale = max(1.0, float(np.abs(v_ref).max()))
    np.testing.assert_allclose(v_jit, v_ref, atol=1e-3 * scale)
    np.testing.assert_allclose(res_jit.violation_history,
                               res_ref.violation_history, atol=1e-2)


@pytest.mark.parametrize("cfg,transform,zero_ue", GRID,
                         ids=["base", "single_bs", "zero_data_ue",
                              "cut_server_mesh"])
@pytest.mark.parametrize("distributed", [False, True],
                         ids=["centralized", "distributed"])
def test_jit_matches_ref(cfg, transform, zero_ue, distributed):
    net = make_network(cfg)
    if transform is not None:
        net = transform(net)
    D_bar = _d_bar(net, zero_ue)
    res_ref, res_jit = _solve_both(net, D_bar, distributed)
    _assert_parity(net, D_bar, res_ref, res_jit)


def test_warm_resolve_hits_compile_cache(assert_no_retrace):
    """Re-solving at the same dims with fresh rates / arrivals must NOT
    build a new compiled step (rates are traced args, dims key the
    cache).  Pinned with the process-wide retrace guard (zero XLA
    compiles anywhere, not just a stable sca cache size)."""
    cfg = NetworkConfig(num_ue=6, num_bs=3, num_dc=2, seed=5)
    net = make_network(cfg)
    consts = _consts(net)
    w0 = sca.solve(net, _d_bar(net), consts, OW, distributed=False,
                   max_outer=2, pd=PD, backend="jit").w
    n0 = sca.jit_cache_size()
    rng = np.random.RandomState(1)
    net2 = net.resample_rates(rng, 0.2)
    with assert_no_retrace():
        res = sca.solve(net2, _d_bar(net) * 1.3, consts, OW,
                        distributed=False, max_outer=2, pd=PD,
                        backend="jit", w0=w0)
    assert sca.jit_cache_size() == n0
    assert len(res.objective_history) >= 2


def test_netview_roundtrip_and_flat_spec():
    net = make_network(NetworkConfig(num_ue=5, num_bs=2, num_dc=2, seed=4))
    nv = NetView.from_network(net)
    assert nv.dims == net.dims
    np.testing.assert_allclose(np.asarray(nv.R_nb),
                               np.asarray(net.R_nb, np.float32))
    spec = WSpec(net.dims)
    w = project(init_w(net, _d_bar(net)), net)
    back = spec.unflatten(spec.flatten(w))
    for k in w:
        np.testing.assert_allclose(np.asarray(back[k]),
                                   np.asarray(w[k], np.float32), rtol=1e-6)
