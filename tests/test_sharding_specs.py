"""Unit tests for the PartitionSpec rule layer (sharding/specs.py): the
path-keyed param/cache rules, the sanitize divisibility degradation, and
the ShardCtx presets.  Mesh-free (specs only inspect ``mesh.shape``), so
these run on the single-device tier-1 lane too."""
import types

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import (batch_spec, cache_specs, param_specs,
                                  sanitize_spec, sanitize_tree,
                                  shard_ctx_for)


def _mesh(**axes):
    # sanitize_spec / plane_axes only read mesh.shape[name]
    return types.SimpleNamespace(shape=dict(axes))


MESH = _mesh(data=2, model=16)


# ----------------------------------------------------- sanitize_spec -----

def test_sanitize_spec_degrades_non_dividing_axis():
    # kv-heads = 8 on a 16-way model axis: the classic non-divisible case
    assert sanitize_spec(P(None, None, "model", None),
                         (4, 128, 8, 64), MESH) == \
        P(None, None, None, None)
    # 32 heads divide 16: kept
    assert sanitize_spec(P(None, None, "model", None),
                         (4, 128, 32, 64), MESH) == \
        P(None, None, "model", None)


def test_sanitize_spec_tuple_entries_use_axis_product():
    big = _mesh(data=2, model=4)
    assert sanitize_spec(P(("data", "model")), (32,), big) == \
        P(("data", "model"))
    assert sanitize_spec(P(("data", "model")), (12,), big) == P(None)


def test_sanitize_spec_short_spec_pads_with_replication():
    out = sanitize_spec(P("data"), (8, 16, 32), MESH)
    assert out == P("data", None, None)


def test_sanitize_tree_maps_over_pytrees():
    specs = {"a": P("model"), "b": P("data", None)}
    shapes = {"a": np.zeros((48,)), "b": np.zeros((7, 3))}
    out = sanitize_tree(specs, shapes, MESH)
    assert out["a"] == P("model")       # 48 % 16 == 0
    assert out["b"] == P(None, None)    # 7 % 2 != 0


def test_plane_axes_divisibility_degradation():
    from repro.sharding.plane import plane_axes
    mesh = _mesh(dpu=4, rows=2)
    assert plane_axes(mesh, 8, 16) == ("dpu", "rows")
    # ragged DPU group: dpu degrades, rows survive
    assert plane_axes(mesh, 7, 16) == (None, "rows")
    # no leading axis at all (master plane)
    assert plane_axes(mesh, None, 16) == (None, "rows")
    # rows not divisible by the rows axis
    assert plane_axes(_mesh(dpu=4, rows=3), 8, 16) == ("dpu", None)


# -------------------------------------------------------- param rules ----

def _fake_params():
    """Path-named pytree exercising every rule family: top-level embeds,
    stacked attention / mlp / mamba / moe blocks, final norm."""
    z = np.zeros
    return {
        "embed": z((512, 64)),
        "pos_embed": z((128, 64)),
        "blocks": {
            "attn": {"wq": z((2, 64, 8, 16)), "wo": z((2, 8, 16, 64)),
                     "ln": z((2, 64))},
            "mlp": {"w_in": z((2, 64, 256)), "w_out": z((2, 256, 64))},
            "mamba": {"w_in": z((2, 64, 128)), "w_out": z((2, 128, 64)),
                      "conv_w": z((2, 4, 128)), "norm": z((2, 128))},
            "moe": {"router": z((2, 64, 8)),
                    "w_in": z((2, 8, 64, 256)),
                    "w_out": z((2, 8, 256, 64))},
        },
        "final_norm": z((64,)),
        "unembed": z((64, 512)),
    }


def test_param_specs_cover_attention_mlp_mamba_moe():
    specs = param_specs(None, _fake_params())
    assert specs["embed"] == P("model", "data")
    assert specs["unembed"] == P("data", "model")
    assert specs["pos_embed"] == P(None, "data")
    blocks = specs["blocks"]
    # stacked blocks get a replicated leading layer axis
    assert blocks["attn"]["wq"] == P(None, "data", "model", None)
    assert blocks["attn"]["wo"] == P(None, "model", None, "data")
    assert blocks["attn"]["ln"] == P(None, None)
    assert blocks["mlp"]["w_in"] == P(None, "data", "model")
    assert blocks["mlp"]["w_out"] == P(None, "model", "data")
    assert blocks["mamba"]["w_in"] == P(None, "data", "model")
    assert blocks["mamba"]["w_out"] == P(None, "model", "data")
    assert blocks["mamba"]["conv_w"] == P(None, None, "model")
    assert blocks["mamba"]["norm"] == P(None, "model")
    assert blocks["moe"]["router"] == P(None, "data", None)
    assert blocks["moe"]["w_in"] == P(None, "model", "data", None)
    assert blocks["moe"]["w_out"] == P(None, "model", None, "data")
    assert specs["final_norm"] == P(None)


def test_param_specs_custom_axis_names():
    specs = param_specs(None, {"embed": np.zeros((8, 8))},
                        data="rows", model="dpu")
    assert specs["embed"] == P("dpu", "rows")


# -------------------------------------------------------- cache rules ----

def _fake_cache():
    z = np.zeros
    return {"layers": {"k": z((2, 4, 128, 8, 64)),
                       "v": z((2, 4, 128, 8, 64)),
                       "xk": z((2, 4, 128, 8, 64)),
                       "h": z((2, 4, 8, 64, 16)),
                       "conv": z((2, 4, 3, 128))},
            "pos": z(())}


def test_cache_specs_default_and_wide():
    specs = cache_specs(None, _fake_cache())
    lay = specs["layers"]
    assert lay["k"] == P(None, ("data",), ("model",), None, None)
    assert lay["v"] == P(None, ("data",), ("model",), None, None)
    # xk/xv: cross-attention keys are not sequence-sharded
    assert lay["xk"] == P(None, ("data",), None, None, None)
    assert lay["h"] == P(None, ("data",), None, None, None)
    assert lay["conv"] == P(None, ("data",), None, None)
    assert specs["pos"] == P()

    wide = cache_specs(None, _fake_cache(), batch_axes=(),
                       seq_axes=("model", "data"))
    assert wide["layers"]["k"] == P(None, (), ("model", "data"), None,
                                    None)
    off = cache_specs(None, _fake_cache(), seq_shard=False)
    assert off["layers"]["k"] == P(None, ("data",), None, None, None)


def test_shard_ctx_for_wide_cache_moves_data_axis():
    mesh = _mesh(data=2, model=4)
    ctx = shard_ctx_for(mesh, multi_pod=False, seq_shard_decode=True)
    assert ctx.batch_axes == ("data",)
    assert ctx.cache_axes == ("model",)

    wide = shard_ctx_for(mesh, multi_pod=False, seq_shard_decode=True,
                         wide_cache=True)
    # long-context b=1: the data axis leaves batch and joins the cache seq
    assert wide.batch_axes == ()
    assert wide.cache_axes == ("model", "data")

    pod = shard_ctx_for(mesh, multi_pod=True, seq_shard_decode=False,
                        wide_cache=True)
    assert pod.batch_axes == ("pod",)


def test_batch_spec():
    assert batch_spec(True) == ("pod", "data")
    assert batch_spec(False) == ("data",)
